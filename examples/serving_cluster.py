"""Online-serving walkthrough: latency-utility curves, SLO risk, and the
headroom-holding policy layer next to batch work (arXiv 2201.09050).

    PYTHONPATH=src python examples/serving_cluster.py [--batch 8] [--headroom 1.3]

1. The serving model on one fleet: M/M/1-coarse p99 vs utilization, the
   closed-form SLO-feasible ceiling, and where the utility-risk edge sits.
2. Compose a scheduler with `SLOLayer` (the same policy-stack API every
   axis uses) and show the stack.
3. Run the diurnal serving trace (two inference fleets + batch filler)
   on the OU spot market under eva-slo vs the headroom-blind stack vs a
   batch-only anchor, and compare attainment / cost / replica churn.
"""
import argparse

from repro.cluster import SimConfig, Simulator, serving_trace
from repro.core import (EvaScheduler, PriceModel, aws_catalog,
                        p99_latency_ms)
from repro.policies import SLOLayer, SpotLayer

ap = argparse.ArgumentParser()
ap.add_argument("--batch", type=int, default=8,
                help="batch filler jobs next to the two serving fleets")
ap.add_argument("--headroom", type=float, default=1.3,
                help="planning-demand inflation for replicas (1.0 = off)")
args = ap.parse_args()

pm = PriceModel.mean_reverting(discount=0.35, seed=7)
jobs = serving_trace(n_batch=args.batch, horizon_h=6.0, seed=17)
fleets = [j for j in jobs if j.is_service]

# -- 1. the serving model: latency is a closed-form map of headroom ----------
print("serving fleets (utility = 1.0 at/below target p99, decay beyond):")
for j in fleets:
    s = j.service
    ceiling = s.max_utilization()
    print(f"  job {j.job_id}: {j.n_tasks} replicas x "
          f"{s.per_replica_rps:g} rps, base p99 {s.base_latency_ms:g} ms, "
          f"target {s.utility.target_p99_ms:g} ms")
    print(f"    p99 = base/(1-rho): rho<= {ceiling:.2f} meets target; "
          f"risk edge at rho = {s.risk_fraction * ceiling:.2f}; "
          f"p99({ceiling:.2f}) = "
          f"{p99_latency_ms(s.base_latency_ms, ceiling):.0f} ms")

# -- 2. a scheduler is Algorithm 1 + a stack of policy layers ----------------
cat = aws_catalog(price_model=pm)
layer = SLOLayer(headroom=args.headroom)
sched = EvaScheduler(cat, policies=[SpotLayer(), layer])
print(f"\npolicy stack: {sched.stack.describe()}")
print(f"SLOLayer: headroom={layer.headroom:g} (planning-view CPU/RAM "
      "inflation), warm-keep exemption while at risk, risk-damped "
      "repacking, capacity-aware move veto")

# -- 3. schedulers head to head ----------------------------------------------
print(f"\ntwo fleets + {args.batch} batch jobs, 6h diurnal window with "
      "surges, OU spot market")
runs = (
    ("eva-slo", [SpotLayer(), SLOLayer(headroom=args.headroom)]),
    ("eva-blind", [SpotLayer()]),
    ("batch-only", [SpotLayer()]),
)
results = {}
for name, layers in runs:
    c = aws_catalog(price_model=pm)
    s = EvaScheduler(c, policies=layers)
    fresh = serving_trace(n_batch=args.batch, horizon_h=6.0, seed=17)
    if name == "batch-only":
        fresh = [j for j in fresh if not j.is_service]
    m = Simulator(c, fresh, s,
                  SimConfig(seed=5, preemption_hazard_per_hour=0.25)).run()
    results[name] = m
    serving = (f"  attainment={m.slo_attainment:.4f} "
               f"utility={m.service_utility:.4f} "
               f"signals={m.slo_pressure_signals}" if m.has_service else "")
    print(f"  {name:10s} ${m.total_cost:7.2f}{serving}")

slo, blind = results["eva-slo"], results["eva-blind"]
anchor = results["batch-only"]
print(f"\neva-slo holds p99-SLO attainment at {slo.slo_attainment:.1%} vs "
      f"the blind stack's {blind.slo_attainment:.1%}, at "
      f"{slo.total_cost / blind.total_cost - 1.0:+.1%} cost "
      f"({slo.total_cost / anchor.total_cost - 1.0:+.1%} over the "
      "batch-only anchor) - headroom is bought, not hoped for")
