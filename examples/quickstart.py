"""Quickstart: pack a task set with Eva's Full Reconfiguration (Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's Table-3 walkthrough, then packs a 60-task set from
the Table-7 workloads and compares against No-Packing and the ILP bound.
"""
import numpy as np

from repro.core import (TaskSet, aws_catalog, full_reconfiguration, make_task,
                        reservation_prices, table3_catalog)
from repro.core.cluster_types import Task
from repro.core.ilp import cost_lower_bound
from repro.core.workloads import NUM_WORKLOADS, WORKLOADS

# --- 1. the paper's worked example (Table 3) -------------------------------
tasks = TaskSet([Task(i, i, i, {"p3": d}) for i, d in enumerate(
    [(2.0, 8.0, 24.0), (1.0, 4.0, 10.0), (0.0, 6.0, 20.0), (0.0, 4.0, 12.0)])])
cat3 = table3_catalog()
cfg = full_reconfiguration(tasks, cat3, interference_aware=False,
                           multi_task_aware=False)
print("Table-3 walkthrough:")
for k, tids in cfg.assignments:
    print(f"  {cat3.types[k].name}: tasks {sorted(tids)}")
print(f"  packed ${cfg.total_hourly_cost(cat3):.1f}/hr vs "
      f"${reservation_prices(tasks, cat3).sum():.1f}/hr separate\n")

# --- 2. a real instance catalog + Table-7 workloads ------------------------
rng = np.random.default_rng(0)
cat = aws_catalog()
ts = TaskSet([make_task(job_id=i, workload=int(rng.integers(NUM_WORKLOADS)))
              for i in range(60)])
rp = reservation_prices(ts, cat)
packed = full_reconfiguration(ts, cat, interference_aware=False,
                              multi_task_aware=False)
lb = cost_lower_bound(ts, cat)
print(f"60 tasks from {len(WORKLOADS)} Table-7 workloads:")
print(f"  No-Packing (one instance per task): ${rp.sum():8.2f}/hr")
print(f"  Eva Full Reconfiguration:           ${packed.total_hourly_cost(cat):8.2f}/hr"
      f"  ({packed.total_hourly_cost(cat)/rp.sum()*100:.1f}%)")
print(f"  resource lower bound:               ${lb:8.2f}/hr")
print(f"  instances: {len(packed.assignments)} "
      f"(tasks/instance {60/len(packed.assignments):.2f})")
