"""Burstable-credit (CASH) walkthrough: credit dynamics, credit-adjusted
reservation prices, and the credit-aware Eva scheduler.

    PYTHONPATH=src python examples/burstable_cluster.py [--jobs 16]

1. Watch a burstable instance's credit balance drain and its effective
   speed collapse to the baseline while the hourly bill stays flat.
2. Price a burstable type the credit-aware way: effective $/throughput
   over a planning horizon, from a fresh launch and from an exhausted
   balance.
3. Run the same CPU trace under credit-aware Eva, credit-blind Eva and
   on-demand Eva, and compare cost / JCT / throttled hours.
"""
import argparse

from repro.cluster import SimConfig, Simulator, burstable_trace
from repro.policies import CreditLayer, SpotLayer
from repro.core import (EvaScheduler, TaskSet, aws_catalog,
                        burstable_demo_catalog, make_task,
                        reservation_prices)

ap = argparse.ArgumentParser()
ap.add_argument("--jobs", type=int, default=16)
args = ap.parse_args()

# -- 1. the credit state machine ---------------------------------------------
cat = burstable_demo_catalog()
k = cat.index_of("t7i.2xlarge")
cm = cat.credit_models[k]
print(f"t7i.2xlarge: ${cat.costs[k]:.3f}/h "
      f"(c7i.2xlarge on demand: ${cat.costs[cat.index_of('c7i.2xlarge')]:.3f}/h)")
print(f"credit model: baseline {cm.baseline_fraction:.0%}, accrual "
      f"{cm.accrual_per_hour:.2f} h/h, launch {cm.launch_credit_hours:g} h, "
      f"cap {cm.credit_cap_hours:g} h")
bal = cm.launch_credit_hours
print("busy at full duty, the balance drains at "
      f"{cm.drain_per_hour():.2f} h/h -> throttles after "
      f"{cm.burst_hours(bal):.2f} h busy:")
for t_h in (0.0, 0.25, 0.5, 0.625, 1.0):
    b = max(0.0, bal - cm.drain_per_hour() * t_h)
    print(f"  t={t_h:5.3f}h  balance={b:5.2f}h  speed={cm.speed(b):4.0%}"
          f"  bill=${cat.costs[k]:.3f}/h (unchanged)")

# -- 2. credit-adjusted reservation prices -----------------------------------
tasks = TaskSet([make_task(job_id=1, workload=8)])  # diamond: 8 vCPU / 16 GB
for label, horizon_s in (("30 min", 1800.0), ("2 h", 7200.0), ("8 h", 28800.0)):
    rp = reservation_prices(tasks, cat, credit_horizon_s=horizon_s)
    plain = reservation_prices(tasks, cat)
    print(f"RP(diamond) over {label:6s} horizon: ${rp[0]:.3f}/h "
          f"(sticker-price RP: ${plain[0]:.3f}/h)")
print("-> a burstable type is cheap only while its forecast credits last;\n"
      "   past the burst window its effective price exceeds the on-demand twin")

# -- 3. schedulers head to head ----------------------------------------------
print(f"\n{args.jobs} CPU jobs on the burstable demo market")
results = {}
for name in ("eva-credit", "eva-blind", "eva-ondemand"):
    if name == "eva-credit":
        c = burstable_demo_catalog()
        sched = EvaScheduler(c, policies=[SpotLayer(), CreditLayer()])
    elif name == "eva-blind":
        c = burstable_demo_catalog()
        sched = EvaScheduler(c)
    else:
        c = aws_catalog()
        sched = EvaScheduler(c)
    jobs = burstable_trace(n_jobs=args.jobs, seed=11)
    m = Simulator(c, jobs, sched, SimConfig(seed=5)).run()
    results[name] = m
    extra = ""
    if m.has_credits:
        extra = (f"  exhaustions={m.credit_exhaustions}"
                 f" throttled={m.throttled_s / 3600.0:.1f}h"
                 f" drains={sched.credit_drains}")
    print(f"  {name:13s} ${m.total_cost:7.2f}  jct={m.avg_jct_hours:5.2f}h"
          f"  migrations={m.migrations}{extra}")

save_blind = 1.0 - (results["eva-credit"].total_cost
                    / results["eva-blind"].total_cost)
save_od = 1.0 - (results["eva-credit"].total_cost
                 / results["eva-ondemand"].total_cost)
print(f"\ncredit-aware Eva saves {save_blind:.1%} vs credit-blind Eva "
      f"(escapes the throttle) and {save_od:.1%} vs on-demand Eva "
      "(harvests the cheap burst window)")
