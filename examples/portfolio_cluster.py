"""Commitment-portfolio walkthrough: reserved pools next to spot markets,
provider-qualified prices, and the portfolio Eva scheduler.

    PYTHONPATH=src python examples/portfolio_cluster.py [--pool 6] [--hazard 0.25]

1. Build a two-provider market (aws with a 1yr commitment pool on
   c7i.2xlarge, gcp with its own spot process) and look at the price
   ladder: committed rate < spot mean < on-demand.
2. Price a cross-provider move: egress out of the source cloud + the thin
   inter-cloud link, vs the free market -> pool move inside a provider.
3. Run the bundled steady+bursty trace under the portfolio stack, pure
   spot, and a peak-sized pure commitment, and compare total cost /
   pool utilization / idle waste / per-provider spend.
"""
import argparse
import math

from repro.cluster import SimConfig, Simulator, portfolio_trace
from repro.core import (CommitmentModel, EvaScheduler, PriceModel, Provider,
                        checkpoint_size_gb, multi_provider_catalog)
from repro.policies import MultiRegionLayer, PortfolioLayer, SpotLayer

ap = argparse.ArgumentParser()
ap.add_argument("--pool", type=int, default=6,
                help="committed c7i.2xlarge slots (the steady base)")
ap.add_argument("--hazard", type=float, default=0.25,
                help="baseline preemptions per instance-hour at mean price")
args = ap.parse_args()

COMMIT = "c7i.2xlarge"
RATE_FRACTION = 0.4  # 1yr committed rate as a fraction of on-demand


def build_catalog(pool_size, seed=7):
    commitments = (CommitmentModel(instance_type=COMMIT, pool_size=pool_size,
                                   rate_fraction=RATE_FRACTION),) \
        if pool_size else ()
    return multi_provider_catalog((
        Provider(name="aws",
                 price_model=PriceModel.mean_reverting(discount=0.6,
                                                       seed=seed),
                 commitments=commitments),
        Provider(name="gcp", cost_scale=1.04,
                 price_model=PriceModel.mean_reverting(discount=0.62,
                                                       seed=seed + 1))))


# -- 1. the price ladder -----------------------------------------------------
cat = build_catalog(args.pool)
k_od = cat.index_of(f"aws/{COMMIT}")
k_pool = cat.index_of(f"aws/commit-{COMMIT}/{COMMIT}")
od = cat.costs[k_od]
print(f"{COMMIT} price ladder on the aws side:")
print(f"  on-demand        ${od:.4f}/h")
print(f"  spot (mean)      ${od * 0.6:.4f}/h  (OU process around 0.60x)")
print(f"  1yr committed    ${cat.costs[k_pool]:.4f}/h  "
      f"({RATE_FRACTION:.0%} of on-demand, billed used-or-idle)")

# -- 2. what moves cost across the portfolio ---------------------------------
w = 3  # cyclegan: 7 GB checkpoint
gb = checkpoint_size_gb(w)
r_aws, r_pool = cat.region_of(k_od), cat.region_of(k_pool)
r_gcp = cat.region_of(cat.index_of(f"gcp/{COMMIT}"))
print(f"\nmoving a {gb:.0f} GB checkpoint:")
print(f"  aws market -> aws pool   "
      f"${cat.transfer.egress_usd(r_aws, r_pool, gb):.2f} egress, "
      f"{cat.transfer.transfer_time_s(r_aws, r_pool, gb):.1f}s "
      "(intra-provider: free, fat link)")
print(f"  aws market -> gcp market "
      f"${cat.transfer.egress_usd(r_aws, r_gcp, gb):.2f} egress, "
      f"{cat.transfer.transfer_time_s(r_aws, r_gcp, gb):.1f}s "
      "(cross-provider: source cloud bills data out)")

# -- 3. portfolio vs the pure regimes ----------------------------------------
n_steady, n_burst = args.pool, 10
peak = n_steady + math.ceil(n_burst / 2)
print(f"\n{n_steady} steady horizon-long jobs + {n_burst} bursty jobs, "
      f"hazard {args.hazard}/instance-hour")
results = {}
for label, pool in (("eva-portfolio", args.pool),
                    ("pure-spot", 0),
                    ("pure-commit", peak)):
    c = build_catalog(pool)
    layers = [SpotLayer(), MultiRegionLayer()]
    if pool:
        layers.append(PortfolioLayer())
    jobs = portfolio_trace(n_steady=n_steady, n_burst=n_burst, seed=23)
    sched = EvaScheduler(c, policies=layers)
    cfg = SimConfig(seed=5, preemption_hazard_per_hour=args.hazard)
    m = Simulator(c, jobs, sched, cfg).run()
    results[label] = m
    extra = ""
    if pool:
        util = next(iter(m.commitment_utilization.values()))
        extra = (f"  commit=${m.commitment_cost:.2f}"
                 f" idle=${m.commitment_idle_cost:.2f}"
                 f" util={util:.0%}")
    spend = ", ".join(f"{p}=${v:.2f}"
                      for p, v in sorted(m.cost_by_provider.items()))
    print(f"  {label:14s} ${m.total_cost:7.2f}  [{spend}]{extra}")

port = results["eva-portfolio"].total_cost
print(f"\nportfolio saves "
      f"{1.0 - port / results['pure-spot'].total_cost:.1%} vs pure-spot and "
      f"{1.0 - port / results['pure-commit'].total_cost:.1%} vs pure-commit "
      "(the steady base rides the discounted pool, bursts overflow to "
      "whichever spot market is cheap, and idle commitment waste stays "
      "near zero)")
