"""Spot-market walkthrough: dynamic prices, revocations, and spot-aware Eva.

    PYTHONPATH=src python examples/spot_cluster.py [--jobs 24] [--hazard 0.5]

1. Attach a mean-reverting PriceModel to the AWS catalog and inspect how the
   price of one instance type drifts (and how the Algorithm-1 packing order
   can change with it).
2. Run the same trace under spot-aware Eva (dynamic prices + preemptions),
   on-demand Eva, and No-Packing, and compare cost / JCT / preemptions.
"""
import argparse

from repro.cluster import SimConfig, Simulator, physical_trace
from repro.policies import SpotLayer
from repro.core import (EvaScheduler, NoPackingScheduler, PriceModel,
                        aws_catalog)

ap = argparse.ArgumentParser()
ap.add_argument("--jobs", type=int, default=24)
ap.add_argument("--hazard", type=float, default=0.5,
                help="baseline preemptions per instance-hour at mean price")
args = ap.parse_args()

# -- 1. price dynamics ------------------------------------------------------
pm = PriceModel.mean_reverting(discount=0.35, volatility=0.10, seed=7)
spot_cat = aws_catalog(price_model=pm)
k = spot_cat.index_of("p3.8xlarge")
print("p3.8xlarge on-demand: $%.2f/h; spot price over the first day:"
      % spot_cat.costs[k])
for hour in (0, 4, 8, 12, 16, 20, 24):
    snap = spot_cat.at(hour * 3600.0)
    print(f"  t={hour:2d}h  ${snap.costs[k]:6.3f}/h   "
          f"(x{snap.costs[k] / spot_cat.costs[k]:.2f}, "
          f"rank {list(snap.order_desc).index(k)} in packing order)")

# -- 2. schedulers head to head --------------------------------------------
print(f"\n{args.jobs} jobs, hazard {args.hazard}/instance-hour, "
      "2-min revocation notice")
results = {}
for name in ("eva-spot", "eva", "no-packing"):
    jobs = physical_trace(n_jobs=args.jobs, seed=11,
                          duration_range_h=(0.3, 0.8))
    if name == "eva-spot":
        cat = aws_catalog(price_model=pm)
        sched = EvaScheduler(cat, policies=[SpotLayer()])
        cfg = SimConfig(seed=5, preemption_hazard_per_hour=args.hazard)
    else:
        cat = aws_catalog()
        sched = (EvaScheduler(cat) if name == "eva"
                 else NoPackingScheduler(cat))
        cfg = SimConfig(seed=5)
    m = Simulator(cat, jobs, sched, cfg).run()
    results[name] = m
    extra = ""
    if name == "eva-spot":
        extra = (f" notices={m.preemption_notices}"
                 f" preempted={m.preemptions}"
                 f" forced_partials={sched.forced_partials}")
    print(f"  {name:10s} ${m.total_cost:8.2f}  jct={m.avg_jct_hours:5.2f}h"
          f"  migrations={m.migrations}{extra}")

saving = 1.0 - results["eva-spot"].total_cost / results["eva"].total_cost
print(f"\nspot-aware Eva saves {saving:.1%} vs on-demand Eva "
      "(pays spot prices; revocation losses bounded by the checkpoint period)")
